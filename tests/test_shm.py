"""Wire hot-path tests: quantized float framing (F16/Q8), zero-copy
scatter-gather encoding, TRAJ batching, the shared-memory ring, and the
ShmTransport end-to-end contracts.

The load-bearing ones mirror `test_transport.py`'s philosophy: a shm
rollout with quantization OFF must be BIT-identical to the in-process
backend (the ring replaces only the byte carriage), and the best-of-N
ping probe must show the ring no slower than loopback TCP — the whole
reason the transport exists.
"""

import io
import time

import numpy as np
import pytest

from repro.core.inference import InferenceServer, ReplyError
from repro.envs.catch import CatchEnv
from repro.launch.actor_host import ActorHostPool
from repro.transport import codec
from repro.transport.shm import (DEFAULT_NUM_SLOTS, DEFAULT_SLOT_SIZE,
                                 ShmRing, ShmRingError)
from repro.transport.socket import (InferenceGateway, ShmTransport,
                                    SyncSocketTransport)


def det_policy(obs, ids):
    flat = np.abs(obs.reshape(obs.shape[0], -1))
    return (flat.sum(axis=1) * 997.0).astype(np.int64) % CatchEnv.num_actions


# ----------------------------------------------------- quantized framing

def test_f16_roundtrip_equals_float16_cast():
    """ENC_F16 is exactly the float16 cast: decode == arr.astype(f16)
    back in f32, and the frame advertises FLAG_F16 at half the raw size."""
    rng = np.random.default_rng(0)
    arr = (rng.standard_normal((8, 50)) * 10).astype(np.float32)
    wire = codec.encode_request(1, 2, arr, quant="f16")
    raw = codec.encode_request(1, 2, arr)
    assert len(wire) < len(raw) - arr.nbytes // 4    # ~2x on the payload
    frame = codec.decode_frame(wire[4:])
    assert frame.flags & codec.FLAG_F16
    assert frame.array.dtype == np.float32
    np.testing.assert_array_equal(
        frame.array, arr.astype(np.float16).astype(np.float32))


def test_f16_skipped_on_overflow_and_nonfinite():
    """Values outside float16 range (or inf/nan anywhere) must ship raw —
    a lossy codec that minted infs would corrupt the policy input."""
    big = np.array([[1e6, 1.0]], np.float32)         # > 65504
    frame = codec.decode_frame(
        codec.encode_request(1, 2, big, quant="f16")[4:])
    assert not frame.flags & codec.FLAG_F16
    np.testing.assert_array_equal(frame.array, big)
    naughty = np.array([[np.inf, 0.5]], np.float32)
    frame = codec.decode_frame(
        codec.encode_request(1, 2, naughty, quant="f16")[4:])
    assert not frame.flags & codec.FLAG_F16
    np.testing.assert_array_equal(frame.array, naughty)


def test_q8_roundtrip_error_bound_and_constant_exactness():
    """ENC_Q8 affine int8: max abs error <= scale/2 where
    scale = (max-min)/255; a constant array decodes EXACTLY (scale 0
    means offset carries the value)."""
    rng = np.random.default_rng(1)
    arr = (rng.random((16, 50)) * 7 - 3).astype(np.float32)
    wire = codec.encode_request(3, 4, arr, quant="q8")
    raw = codec.encode_request(3, 4, arr)
    assert len(wire) < len(raw) // 3                 # ~4x on the payload
    frame = codec.decode_frame(wire[4:])
    assert frame.flags & codec.FLAG_Q8
    assert frame.array.dtype == np.float32
    scale = (float(arr.max()) - float(arr.min())) / 255.0
    assert np.abs(frame.array - arr).max() <= scale / 2 + 1e-6
    const = np.full((4, 50), 2.5, np.float32)
    out = codec.decode_frame(
        codec.encode_request(1, 1, const, quant="q8")[4:])
    assert out.flags & codec.FLAG_Q8
    np.testing.assert_array_equal(out.array, const)


def test_quant_only_when_smaller_and_only_f32():
    # tiny f32 arrays: the 8-byte q8 prologue eats the win -> raw
    tiny = np.zeros(2, np.float32)
    assert not codec.decode_frame(
        codec.encode_request(1, 1, tiny, quant="q8")[4:]).flags \
        & codec.FLAG_Q8
    # non-f32 payloads never quantize, whatever was requested
    for a in (np.zeros((4, 50), np.float64), np.zeros((4, 50), np.uint8),
              np.zeros((4, 50), np.int32)):
        f = codec.decode_frame(codec.encode_request(1, 1, a, quant="f16")[4:])
        assert not f.flags & (codec.FLAG_F16 | codec.FLAG_Q8)
        assert f.array.dtype == a.dtype
    with pytest.raises(codec.CodecError, match="quant"):
        codec.encode_request(1, 1, np.zeros((4, 50), np.float32),
                             quant="lz4")


def test_quant_property_roundtrip_bounds():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=40)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(3, 200),
           st.sampled_from(["f16", "q8"]),
           st.floats(0.01, 1e4))
    def roundtrip(seed, n, quant, span):
        rng = np.random.default_rng(seed)
        arr = ((rng.random(n) - 0.5) * span).astype(np.float32)
        frame = codec.decode_frame(
            codec.encode_request(1, seed, arr, quant=quant)[4:])
        assert frame.array.dtype == np.float32
        assert frame.array.shape == arr.shape
        if quant == "f16" and frame.flags & codec.FLAG_F16:
            np.testing.assert_array_equal(
                frame.array, arr.astype(np.float16).astype(np.float32))
        elif quant == "q8" and frame.flags & codec.FLAG_Q8:
            scale = (float(arr.max()) - float(arr.min())) / 255.0
            assert np.abs(frame.array - arr).max() <= scale / 2 + 1e-6
        else:                                         # fell back to raw
            np.testing.assert_array_equal(frame.array, arr)

    roundtrip()


def test_traj_quant_applies_only_to_obs_key():
    """Lossy framing is an obs-only concession: rewards/dones/logprobs in
    the same TRAJ must stay bit-exact or the learner's targets drift."""
    traj = {"obs": np.random.rand(8, 50).astype(np.float32),
            "rewards": np.random.rand(8).astype(np.float32) * 100,
            "dones": np.zeros(8, np.float32)}
    out = codec.decode_frame(
        codec.encode_trajectory(1, traj, quant="q8")[4:])
    assert out.flags & codec.FLAG_Q8
    scale = (float(traj["obs"].max()) - float(traj["obs"].min())) / 255.0
    assert np.abs(out.arrays["obs"] - traj["obs"]).max() <= scale / 2 + 1e-6
    np.testing.assert_array_equal(out.arrays["rewards"], traj["rewards"])
    np.testing.assert_array_equal(out.arrays["dones"], traj["dones"])


# --------------------------------------- zero-copy parts + TRAJ batching

def test_parts_encoding_matches_joined_and_shares_memory():
    """encode_*_parts is the same bytes as encode_* without the copy: the
    data part is a memoryview over the caller's array."""
    arr = np.random.rand(16, 84).astype(np.float32)
    parts = codec.encode_request_parts(7, 9, arr)
    joined = b"".join(bytes(p) for p in parts)
    assert joined == codec.encode_request(7, 9, arr)
    assert codec.parts_len(parts) == len(joined)
    views = [p for p in parts if isinstance(p, memoryview)]
    assert any(getattr(v, "obj", None) is arr for v in views), \
        "request payload was copied, not viewed"
    # trajectory + reply parts agree with their joined forms too
    traj = {"obs": arr, "a": np.arange(16, dtype=np.int64)}
    assert b"".join(bytes(p) for p in
                    codec.encode_trajectory_parts(3, traj)) == \
        codec.encode_trajectory(3, traj)
    assert b"".join(bytes(p) for p in
                    codec.encode_reply_parts(5, arr, version=2)) == \
        codec.encode_reply(5, arr, version=2)


def test_zero_copy_decode_views_when_aligned():
    """zero_copy=True exposes u8 payloads as read-only views over the recv
    buffer (alignment always holds for u8); writes must be refused."""
    arr = np.arange(4 * 84 * 84, dtype=np.uint8).reshape(4, 84, 84)
    body = codec.encode_request(1, 1, arr)[4:]
    frame = codec.decode_frame(body, zero_copy=True)
    assert np.array_equal(frame.array, arr)
    assert not frame.array.flags.writeable
    assert frame.array.base is not None, "u8 decode copied despite zero_copy"
    with pytest.raises(ValueError):
        frame.array[0, 0, 0] = 1
    # default path stays a private, writable copy
    frame2 = codec.decode_frame(body)
    frame2.array[0, 0, 0] = 1


def test_traj_batch_roundtrip_and_limits():
    """KIND_TRAJ_BATCH carries N unrolls in one frame; decode returns them
    in order, each with intact keys/dtypes; empty batches are refused."""
    rng = np.random.default_rng(2)
    trajs = [{"obs": rng.random((4, 50)).astype(np.float32),
              "actions": rng.integers(0, 3, 4).astype(np.int32)}
             for _ in range(5)]
    wire = codec.encode_traj_batch(9, trajs)
    frame = codec.decode_frame(wire[4:])
    assert frame.kind == codec.KIND_TRAJ_BATCH and frame.actor_id == 9
    assert len(frame.traj_batch) == 5
    for got, want in zip(frame.traj_batch, trajs):
        assert sorted(got) == sorted(want)
        for k in want:
            assert got[k].dtype == want[k].dtype
            np.testing.assert_array_equal(got[k], want[k])
    # one frame << N solo frames: the header+key dedup is the point
    solo = sum(len(codec.encode_trajectory(9, t)) for t in trajs)
    assert len(wire) < solo
    with pytest.raises(codec.CodecError, match="batch"):
        codec.encode_traj_batch(9, [])


def test_expansion_caps_checked_before_allocation():
    """Hostile quant/RLE frames cannot out-expand max_frame: the declared
    decode size is checked BEFORE any allocation, with a named error."""
    arr = np.zeros(4096, np.float32)
    arr[0] = 1.0                                     # make q8 applicable
    wire = codec.encode_request(1, 1, arr, quant="q8")
    assert codec.decode_frame(wire[4:]).array.size == 4096
    with pytest.raises(codec.CodecError, match="Q8"):
        codec.decode_frame(wire[4:], max_frame=1024)
    wire16 = codec.encode_request(1, 1, arr, quant="f16")
    with pytest.raises(codec.CodecError, match="F16"):
        codec.decode_frame(wire16[4:], max_frame=1024)


# ------------------------------------------------------------- shm ring

def test_shm_ring_roundtrip_and_fill():
    ring = ShmRing.create(slot_size=256, num_slots=4)
    try:
        assert ring.fill() == 0
        assert ring.try_get() is None
        assert ring.try_put([b"hello ", b"world"])
        assert ring.fill() == 1
        peer = ShmRing.attach(ring.name, 256, 4)
        assert peer.try_get() == b"hello world"
        assert peer.try_get() is None
        peer.close()
    finally:
        ring.close()
        ring.unlink()


def test_shm_ring_rejects_oversized_and_overflow_returns_false():
    ring = ShmRing.create(slot_size=64, num_slots=2)
    try:
        assert not ring.try_put([b"x" * 65])          # > slot payload
        assert ring.try_put([b"a"])
        assert ring.try_put([b"b"])
        assert not ring.try_put([b"c"])               # full: caller spills
        assert ring.try_get() == b"a"
        assert ring.try_put([b"c"])                   # space reclaimed
        assert ring.try_get() == b"b"
        assert ring.try_get() == b"c"
    finally:
        ring.close()
        ring.unlink()


def test_shm_ring_fuzz_wraparound_against_deque_model():
    """Randomized put/get against a deque model, with a ring small enough
    that every slot wraps many times — ordering and payload bytes must
    match the model exactly, including zero-length payloads."""
    from collections import deque

    rng = np.random.default_rng(3)
    ring = ShmRing.create(slot_size=128, num_slots=3)
    model = deque()
    try:
        for _ in range(2000):
            if rng.random() < 0.55:
                payload = rng.bytes(int(rng.integers(0, 129)))
                ok = ring.try_put([payload])
                assert ok == (len(model) < 3)
                if ok:
                    model.append(payload)
            else:
                got = ring.try_get()
                want = model.popleft() if model else None
                assert got == want
            assert ring.fill() == len(model)
        while model:
            assert ring.try_get() == model.popleft()
    finally:
        ring.close()
        ring.unlink()


def test_shm_ring_attach_validates_geometry():
    ring = ShmRing.create(slot_size=256, num_slots=4)
    try:
        with pytest.raises(ShmRingError):
            ShmRing.attach(ring.name, 512, 4)         # wrong slot size
        with pytest.raises(ShmRingError):
            ShmRing.attach(ring.name, 256, 8)         # wrong slot count
        with pytest.raises((ShmRingError, FileNotFoundError)):
            ShmRing.attach("psm_does_not_exist_xyz", 256, 4)
        with pytest.raises(ShmRingError):
            ShmRing.create(slot_size=0, num_slots=4)
    finally:
        ring.close()
        ring.unlink()


# ------------------------------------------------- ShmTransport e2e

def _serve(max_batch=4, deadline_ms=2.0, **gw_kwargs):
    srv = InferenceServer(det_policy, max_batch=max_batch,
                          deadline_ms=deadline_ms)
    gw = InferenceGateway(srv, **gw_kwargs)
    srv.start()
    addr = gw.start()
    return srv, gw, addr


def test_shm_transport_rides_ring_and_replies_match_tcp():
    """Loopback negotiation grants CODEC_SHM: requests and replies ride
    the ring pair (zero TCP frames after handshake), and the answers are
    identical to a plain TCP connection on the same gateway."""
    srv, gw, addr = _serve()
    tr = ShmTransport.connect(addr)
    tcp = SyncSocketTransport.connect(addr)
    try:
        assert tr.wait_hello(5.0)
        assert tr.shm_active, "loopback peer was not granted CODEC_SHM"
        obs = np.random.rand(4, 50).astype(np.float32)
        for _ in range(8):
            got = tr.submit_batch(1, obs).get(timeout=5.0)
            assert np.array_equal(got, det_policy(obs, None))
        want = tcp.submit_batch(2, obs).get(timeout=5.0)
        assert np.array_equal(want, det_policy(obs, None))
        assert tr.shm_frames >= 8
        assert tr.shm_replies >= 8
        assert tr.spill_frames == 0
        assert gw.stats["shm_conns"] == 1
        assert gw.stats["shm_frames"] >= 8
    finally:
        tr.close()
        tcp.close()
        gw.stop()
        srv.stop()


def test_shm_transport_spills_oversized_frames_to_tcp():
    """A frame too big for a ring slot must transparently take the TCP
    path (same connection, same ordering guarantees) — never an error,
    never a drop."""
    srv, gw, addr = _serve(max_batch=8)
    tr = ShmTransport.connect(addr, slot_size=512, num_slots=4)
    try:
        assert tr.wait_hello(5.0) and tr.shm_active
        small = np.random.rand(1, 50).astype(np.float32)     # fits
        big = np.random.rand(64, 50).astype(np.float32)      # > 512 bytes
        got = tr.submit_batch(1, small).get(timeout=5.0)
        assert np.array_equal(got, det_policy(small, None))
        got = tr.submit_batch(1, big).get(timeout=5.0)
        assert np.array_equal(got, det_policy(big, None))
        assert tr.shm_frames >= 1
        assert tr.spill_frames >= 1, "oversized frame did not spill to TCP"
    finally:
        tr.close()
        gw.stop()
        srv.stop()


def test_shm_transport_severed_on_gateway_loss():
    """Ring liveness rides the TCP control channel: gateway death poisons
    pending replies and fails subsequent submits fast — no spin-forever
    on a dead ring."""
    srv, gw, addr = _serve()
    tr = ShmTransport.connect(addr)
    try:
        assert tr.wait_hello(5.0) and tr.shm_active
        obs = np.zeros((2, 50), np.float32)
        assert tr.submit_batch(1, obs).get(timeout=5.0) is not None
        gw.stop()
        deadline = time.perf_counter() + 5.0
        out = None
        while time.perf_counter() < deadline:
            out = tr.submit_batch(1, obs).get(timeout=1.0)
            if isinstance(out, ReplyError):
                break
            time.sleep(0.05)
        assert isinstance(out, ReplyError), out
        assert tr.error is not None
    finally:
        tr.close()
        srv.stop()


def test_quant_negotiated_per_connection_e2e():
    """quant='q8' HELLOs CODEC_QUANT; granted requests cross the wire
    quantized (gateway counts them) and still produce correct actions for
    a policy that is quantization-robust by construction."""

    def coarse_policy(obs, ids):
        # bucketed so q8's <=scale/2 error cannot flip the argmax
        return (obs.reshape(obs.shape[0], -1) > 0.5).sum(axis=1) \
            .astype(np.int64) % CatchEnv.num_actions

    srv = InferenceServer(coarse_policy, max_batch=8, deadline_ms=2.0)
    gw = InferenceGateway(srv)
    srv.start()
    addr = gw.start()
    tr_q = SyncSocketTransport.connect(addr, quant="q8")
    tr_p = SyncSocketTransport.connect(addr)
    try:
        assert tr_q.wait_hello(5.0)
        obs = np.zeros((4, 50), np.float32)
        obs[:, ::7] = 1.0
        for _ in range(4):
            got = tr_q.submit_batch(0, obs).get(timeout=5.0)
            assert np.array_equal(got, coarse_policy(obs, None))
        got = tr_p.submit_batch(1, obs).get(timeout=5.0)
        assert np.array_equal(got, coarse_policy(obs, None))
        assert gw.stats["quant_request_frames"] >= 3
        assert gw.stats["request_frames"] >= 5
    finally:
        tr_q.close()
        tr_p.close()
        gw.stop()
        srv.stop()


def test_traj_coalescing_one_frame_many_records():
    """With CODEC_TRAJBATCH granted, buffered unrolls leave as ONE
    TRAJ_BATCH frame at the next flush point; the gateway ledger counts
    both the batch frame and the records it carried."""
    sunk = []
    srv, gw, addr = _serve(sink=sunk.append)
    tr = SyncSocketTransport.connect(addr, coalesce=True)
    try:
        assert tr.wait_hello(5.0)
        traj = {"obs": np.random.rand(4, 50).astype(np.float32),
                "actions": np.zeros(4, np.int32)}
        for _ in range(5):
            tr.send_trajectory(traj)
        # flush point: the next request submit
        tr.submit_batch(0, np.zeros((2, 50), np.float32)).get(timeout=5.0)
        deadline = time.perf_counter() + 5.0
        while len(sunk) < 5 and time.perf_counter() < deadline:
            time.sleep(0.01)
        assert len(sunk) == 5
        assert gw.stats["traj_batch_frames"] == 1, \
            "coalesced records arrived as separate frames"
        assert gw.stats["traj_frames"] == 5
        for t in sunk:
            np.testing.assert_array_equal(t["obs"], traj["obs"])
    finally:
        tr.close()
        gw.stop()
        srv.stop()


def test_shm_ping_no_slower_than_tcp_loopback():
    """THE perf contract, in-process edition: best-of-N round-trips over
    the ring must be no slower than loopback TCP (loose 1.2x threshold —
    the strict gate runs in fig4 --smoke --transport shm)."""
    srv, gw, addr = _serve(max_batch=4, deadline_ms=0.5)
    tcp = SyncSocketTransport.connect(addr)
    shm = ShmTransport.connect(addr)
    obs = np.zeros((4, 50), np.float32)
    try:
        assert shm.wait_hello(5.0) and shm.shm_active

        def ping(tr, aid, n=60):
            for _ in range(15):
                tr.submit_batch(aid, obs).get(timeout=5.0)
            t0 = time.perf_counter()
            for _ in range(n):
                tr.submit_batch(aid, obs).get(timeout=5.0)
            return (time.perf_counter() - t0) / n

        best_tcp = min(ping(tcp, 0) for _ in range(3))
        best_shm = min(ping(shm, 1) for _ in range(3))
        assert best_shm <= best_tcp * 1.2, \
            f"shm {1e6 * best_shm:.0f}us vs tcp {1e6 * best_tcp:.0f}us"
    finally:
        tcp.close()
        shm.close()
        gw.stop()
        srv.stop()


# ------------------------------------------------------------- parity

def _run_inproc_rollout(n_traj):
    from repro.core.actor import Actor

    srv = InferenceServer(det_policy, max_batch=3, deadline_ms=2.0)
    trajs = []
    actor = Actor(0, CatchEnv, srv, lambda t: trajs.append(t),
                  unroll=4, num_envs=3)
    srv.start()
    actor.start()
    deadline = time.perf_counter() + 30.0
    while len(trajs) < n_traj and time.perf_counter() < deadline:
        time.sleep(0.01)
    actor.stop()
    srv.stop()
    actor.join()
    assert len(trajs) >= n_traj
    return trajs[:n_traj]


def _run_shm_rollout(n_traj):
    srv = InferenceServer(det_policy, max_batch=3, deadline_ms=2.0)
    trajs = []
    gw = InferenceGateway(srv, sink=lambda t: trajs.append(t))
    srv.start()
    addr = gw.start()
    # quant=None: bit-parity is only promised with lossless framing
    pool = ActorHostPool(CatchEnv, num_actors=1, envs_per_actor=3, unroll=4,
                         use_shm=True, quant=None)
    stats = pool.run(addr, seconds=2.0)
    gw.stop()
    srv.stop()
    assert stats[0]["error"] is None, stats[0]["error"]
    assert stats[0]["shm_frames"] > 0, "rollout never used the ring"
    assert len(trajs) >= n_traj, \
        f"shm rollout produced {len(trajs)} < {n_traj} unrolls"
    return trajs[:n_traj]


def test_shm_parity_rollouts_bit_identical_to_inproc():
    """The transport contract extends to the ring: same seeds, same
    policy, quantization off -> the unroll stream that crosses the shm
    rings equals the in-proc one, bitwise."""
    n = 6
    a_trajs = _run_inproc_rollout(n)
    b_trajs = _run_shm_rollout(n)
    for i, (ta, tb) in enumerate(zip(a_trajs, b_trajs)):
        assert sorted(ta) == sorted(tb)
        for k in ta:
            va, vb = np.asarray(ta[k]), np.asarray(tb[k])
            assert va.dtype == vb.dtype, (i, k)
            assert np.array_equal(va, vb), f"unroll {i} key {k} diverged"


def test_seed_system_shm_transport_end_to_end():
    """`SeedSystem(transport='shm')`: frames flow over the rings (host
    counters prove it), replay fills, and the run is clean."""
    from repro.core.system import SeedSystem

    sys_ = SeedSystem(env_factory=CatchEnv, policy_step=det_policy,
                      num_actors=2, unroll=8, envs_per_actor=4,
                      deadline_ms=1.0, transport="shm", num_actor_hosts=1)
    sys_.warmup()
    stats = sys_.run(seconds=0.8, with_learner=False)
    assert stats["inference_error"] is None
    assert stats["host_errors"] == []
    assert stats["env_frames"] > 50, stats
    assert stats["host_shm_frames"] > 0, "system run never used the ring"
    assert stats["gateway_shm_conns"] >= 1
    assert len(sys_.replay) > 0
