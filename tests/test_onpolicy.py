"""On-policy training plane: queue admission/accounting, batcher, learner
shutdown, V-trace learning, and `SeedSystem(algo="vtrace")` across all
three backends — plus the r2d2-default parity contract.
"""

import queue as _queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.actor import Actor, flush_lane_unrolls
from repro.core.inference import InferenceServer
from repro.core.learner import BatchSourceClosed, Learner
from repro.core.system import SeedSystem
from repro.envs.catch import CatchEnv
from repro.onpolicy import (Closed, TrajectoryQueue, VTraceBatcher,
                            VTraceLearner, assemble_vtrace_batch,
                            make_device_sampling_policy,
                            make_vtrace_train_step, mlp_actor_critic)
from repro.optim import adamw

OBS_DIM = 50          # CatchEnv() default 10x5


def _unroll(t=4, version=None, value=1.0):
    u = {"obs": np.full((t, 3), value, np.float32),
         "actions": np.zeros((t,), np.int32),
         "rewards": np.ones((t,), np.float32),
         "dones": np.zeros((t,), np.float32),
         "behavior_logprobs": np.full((t,), -0.5, np.float32)}
    if version is not None:
        u["param_version"] = np.int64(version)
    return u


def _ledger_conserved(s):
    return s["frames_generated"] == (s["frames_trained"] + s["frames_dropped"]
                                     + s["frames_pending"])


def _make_state(params, opt):
    return {"params": params, "opt_state": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


# --------------------------------------------------------- TrajectoryQueue

def test_queue_admission_and_conservation():
    version = {"v": 0}
    q = TrajectoryQueue(capacity=4, max_param_lag=2,
                        version_source=lambda: version["v"])
    for i in range(3):
        q.put(_unroll(t=5, version=0))
    assert q.stats()["frames_pending"] == 15
    version["v"] = 10                       # everything pending is now stale
    q.put(_unroll(t=5, version=9))          # lag 1: admitted
    q.put(_unroll(t=5, version=3))          # lag 7: dropped at admission
    out = q.pop_batch(1, timeout=1.0)       # stale heads purged at pop
    assert len(out) == 1
    s = q.stats()
    assert s["frames_trained"] == 5
    assert s["frames_dropped_stale"] == 20  # 3 aged in queue + 1 at the door
    assert s["frames_pending"] == 0
    assert _ledger_conserved(s), s
    q.close()
    assert _ledger_conserved(q.stats())


def test_queue_overflow_evicts_oldest():
    q = TrajectoryQueue(capacity=2)
    for i in range(4):
        q.put(_unroll(t=3, version=i))
    s = q.stats()
    assert s["frames_dropped_overflow"] == 6
    assert _ledger_conserved(s)
    kept = q.pop_batch(2, timeout=1.0)
    # the two FRESHEST unrolls survived (on-policy keeps fresh data)
    assert [int(u["param_version"]) for u in kept] == [2, 3]


def test_queue_close_drains_pending_and_wakes_consumers():
    q = TrajectoryQueue(capacity=8)
    q.put(_unroll(t=4))
    got = []

    def consumer():
        try:
            q.pop_batch(5)                  # more than will ever arrive
        except Closed:
            got.append("closed")

    t = threading.Thread(target=consumer, daemon=True)
    t.start()
    time.sleep(0.1)
    q.close()
    t.join(timeout=2.0)
    assert not t.is_alive()
    assert got == ["closed"]
    s = q.stats()
    assert s["frames_dropped_shutdown"] == 4
    assert s["frames_pending"] == 0
    assert _ledger_conserved(s)
    q.put(_unroll(t=4))                     # post-close puts are counted too
    assert _ledger_conserved(q.stats())


def test_queue_validation():
    with pytest.raises(ValueError):
        TrajectoryQueue(capacity=0)
    with pytest.raises(ValueError):
        TrajectoryQueue(capacity=4, max_param_lag=-1)
    q = TrajectoryQueue(capacity=4)
    with pytest.raises(ValueError):
        q.pop_batch(0)
    with pytest.raises(TimeoutError):
        q.pop_batch(1, timeout=0.05)


# ---------------------------------------------------------------- batcher

def test_assemble_vtrace_batch_shapes_and_discounts():
    unrolls = [_unroll(t=6, version=i) for i in range(3)]
    unrolls[1]["dones"][2] = 1.0
    batch = assemble_vtrace_batch(unrolls, gamma=0.9)
    assert batch["obs"].shape == (3, 6, 3)
    assert batch["actions"].dtype == np.int32
    assert batch["behavior_logprobs"].shape == (3, 6)
    assert batch["discounts"][1, 2] == 0.0          # terminal cuts
    assert batch["discounts"][0, 0] == pytest.approx(0.9)
    assert batch["param_version"].tolist() == [0, 1, 2]
    with pytest.raises(KeyError):
        bad = _unroll(t=6)
        del bad["behavior_logprobs"]
        assemble_vtrace_batch([bad], gamma=0.9)
    with pytest.raises(ValueError):
        assemble_vtrace_batch([], gamma=0.9)


def test_batcher_raises_batch_source_closed():
    q = TrajectoryQueue(capacity=8)
    b = VTraceBatcher(q, batch_size=2, gamma=0.99, poll_timeout_s=0.05)
    q.close()
    with pytest.raises(BatchSourceClosed):
        b()


# ------------------------------------------------- learner shutdown (fix)

def test_learner_stop_poisons_blocking_batch_source():
    """Regression: a batch_fn blocking on an empty on-policy queue used to
    hang stop()/join() forever; the poison seam closes the queue and the
    thread exits promptly and cleanly."""
    q = TrajectoryQueue(capacity=8)
    batcher = VTraceBatcher(q, batch_size=4, poll_timeout_s=None)

    def train_step(state, batch):            # never reached
        return state, {}

    lr = Learner(train_step, {"step": np.zeros(())}, batcher, poison=q.close)
    lr.start()
    time.sleep(0.2)                          # let it block inside pop_batch
    t0 = time.perf_counter()
    lr.stop()
    lr.join(timeout=5.0)
    assert time.perf_counter() - t0 < 2.0, "learner did not stop promptly"
    assert not lr._thread.is_alive()
    assert lr.error is None                  # clean shutdown, not a crash


def test_seed_system_learner_stops_with_empty_replay():
    """Same regression on the replay path: min_replay never reached, the
    polling batch_fn must observe learner.stopped and bail."""

    def policy_step(obs, ids):
        return np.zeros((obs.shape[0],), np.int32)

    def train_step(state, batch):
        return state, {}

    sys_ = SeedSystem(env_factory=CatchEnv, policy_step=policy_step,
                      num_actors=1, unroll=4, train_step=train_step,
                      state={"step": np.zeros(())}, min_replay=10 ** 9)
    sys_.learner.start()
    time.sleep(0.2)
    t0 = time.perf_counter()
    sys_.learner.stop()
    sys_.learner.join(timeout=5.0)
    assert time.perf_counter() - t0 < 2.0
    assert not sys_.learner._thread.is_alive()
    assert sys_.learner.error is None


# --------------------------------------------------- V-trace learner math

def test_vtrace_train_step_learns_catch():
    """Direct (threadless) loop: device-engine rollouts with behavior
    logprobs -> assemble -> train_step; average episode reward on Catch
    must clearly improve. This is the e2e anchor for the on-policy math
    without scheduler noise."""
    from repro.rollout import DeviceRolloutEngine

    def env_factory():
        return CatchEnv(rows=6, cols=4)

    init_fn, apply_fn = mlp_actor_critic(24, 3, hidden=32)
    opt = adamw(3e-3)
    state = _make_state(init_fn(jax.random.PRNGKey(0)), opt)
    step = jax.jit(make_vtrace_train_step(apply_fn, opt, entropy_coef=0.003))
    engine = DeviceRolloutEngine(env_factory,
                                 make_device_sampling_policy(apply_fn),
                                 num_envs=16, unroll=12, with_logprobs=True)

    def avg_return(params, seed):
        ev = DeviceRolloutEngine(env_factory,
                                 make_device_sampling_policy(apply_fn),
                                 num_envs=16, unroll=30, seed=seed,
                                 with_logprobs=True)
        traj = ev.rollout(params)
        return float(traj["rewards"].sum() / max(traj["dones"].sum(), 1.0))

    before = avg_return(state["params"], seed=101)
    for i in range(150):
        traj = engine.rollout(state["params"])
        unrolls = []
        flush_lane_unrolls(traj, unrolls.append)
        batch = assemble_vtrace_batch(unrolls, gamma=0.95)
        state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    after = avg_return(state["params"], seed=101)
    assert after > before + 0.3, (before, after)
    assert after > 0.2, (before, after)


# ------------------------------------- SeedSystem(algo="vtrace") backends

def _vtrace_host_system(transport="inproc", **kw):
    init_fn, apply_fn = mlp_actor_critic(OBS_DIM, 3)
    vl = VTraceLearner(apply_fn, adamw(1e-3))
    params = init_fn(jax.random.PRNGKey(0))
    state = vl.init_state(params)
    policy = vl.sampling_policy(params)
    for lanes in (4, 8):                                   # pre-compile
        policy(np.zeros((lanes, OBS_DIM), np.float32), None)
    vl.warmup(state, batch_size=4, unroll=8, obs_shape=(OBS_DIM,))
    return SeedSystem(env_factory=CatchEnv, policy_step=policy,
                      num_actors=2, unroll=8, envs_per_actor=4,
                      deadline_ms=1.0, transport=transport,
                      algo="vtrace", train_step=vl.train_step, state=state,
                      learner_batch=4, policy_publish=policy.publish, **kw)


def _assert_trained_and_conserved(stats):
    assert stats["learner_error"] is None, stats["learner_error"]
    assert stats["inference_error"] is None, stats["inference_error"]
    assert stats["learner_steps"] > 0, stats
    onp = stats["onpolicy"]
    assert _ledger_conserved(onp), onp
    assert onp["frames_pending"] == 0, onp
    assert onp["frames_trained"] > 0, onp
    assert stats["mean_param_lag"] >= 0.0


def test_vtrace_trains_inproc_host_backend():
    sys_ = _vtrace_host_system(max_param_lag=50)
    sys_.warmup()
    stats = sys_.run(seconds=1.5)
    _assert_trained_and_conserved(stats)
    assert stats["algo"] == "vtrace"
    assert stats["unroll_flushes"] > 0


def test_vtrace_trains_device_backend():
    init_fn, apply_fn = mlp_actor_critic(OBS_DIM, 3)
    vl = VTraceLearner(apply_fn, adamw(1e-3))
    state = vl.init_state(init_fn(jax.random.PRNGKey(0)))
    vl.warmup(state, batch_size=4, unroll=8, obs_shape=(OBS_DIM,))
    sys_ = SeedSystem(env_factory=CatchEnv, backend="device",
                      policy_apply=vl.device_policy_apply(),
                      num_actors=2, unroll=8, envs_per_actor=4,
                      algo="vtrace", train_step=vl.train_step, state=state,
                      learner_batch=4, queue_capacity=32)
    sys_.warmup()
    stats = sys_.run(seconds=1.5)
    _assert_trained_and_conserved(stats)
    # the device engine outruns a real learner: the bounded queue must
    # have dropped (this is the algorithmic knee, measured)
    assert stats["onpolicy"]["frames_dropped"] > 0, stats["onpolicy"]


def test_vtrace_trains_socket_backend():
    sys_ = _vtrace_host_system(transport="socket", num_actor_hosts=1,
                               max_param_lag=100)
    stats = sys_.run(seconds=2.0)
    assert stats["host_errors"] == [], stats["host_errors"]
    _assert_trained_and_conserved(stats)
    assert stats["gateway_traj_frames"] > 0


# ----------------------------------------------------- r2d2 default parity

def det_policy(obs, ids):
    return (np.abs(obs.reshape(obs.shape[0], -1)).sum(axis=1) * 31.0
            ).astype(np.int64) % 3


def _collect_records(version_source):
    srv = InferenceServer(det_policy, max_batch=8, deadline_ms=2.0)
    srv.start()
    records = []
    a = Actor(0, CatchEnv, srv, records.append, unroll=4, num_envs=2,
              version_source=version_source)
    a.vec.reset()
    a.start()
    while len(records) < 8:
        time.sleep(0.01)
    a.stop()
    a.join()
    srv.stop()
    return records[:8]


def test_r2d2_actor_records_bit_identical_with_version_source():
    """The satellite metric must be free: wiring a version_source into the
    default (r2d2) actors changes NOTHING about the records they sink —
    same keys, same dtypes, same bytes."""
    base = _collect_records(version_source=None)
    wired = _collect_records(version_source=lambda: 123)
    for ra, rb in zip(base, wired):
        assert sorted(ra) == sorted(rb) == \
            ["actions", "dones", "obs", "rewards"]
        for k in ra:
            assert ra[k].dtype == rb[k].dtype, k
            assert np.array_equal(ra[k], rb[k]), k


def test_r2d2_default_throughput_and_replay_schema_unchanged():
    sys_ = SeedSystem(env_factory=CatchEnv, policy_step=det_policy,
                      num_actors=2, unroll=4, envs_per_actor=2,
                      deadline_ms=1.0)
    sys_.warmup()
    stats = sys_.run(seconds=0.5, with_learner=False)
    assert stats["algo"] == "r2d2"
    # the ledger keys are schema-stable: present on EVERY run, zero-valued
    # when the vtrace queue is off (scrapers never see keys appear mid-run)
    assert stats["onpolicy"]["frames_generated"] == 0
    assert stats["onpolicy"]["drop_rate"] == 0.0
    assert stats["mean_param_lag"] == 0.0           # no learner published
    batch, idx, w = sys_.replay.sample(2)
    assert sorted(batch) == ["actions", "dones", "obs", "rewards"]


def test_algo_validation():
    with pytest.raises(ValueError, match="algo"):
        SeedSystem(env_factory=CatchEnv, policy_step=det_policy,
                   num_actors=1, unroll=4, algo="ppo")
    # every vtrace-only knob is rejected (not silently ignored) on r2d2
    with pytest.raises(ValueError, match="max_param_lag"):
        SeedSystem(env_factory=CatchEnv, policy_step=det_policy,
                   num_actors=1, unroll=4, max_param_lag=3)
    with pytest.raises(ValueError, match="queue_capacity"):
        SeedSystem(env_factory=CatchEnv, policy_step=det_policy,
                   num_actors=1, unroll=4, queue_capacity=8)
    with pytest.raises(ValueError, match="gamma"):
        SeedSystem(env_factory=CatchEnv, policy_step=det_policy,
                   num_actors=1, unroll=4, gamma=0.9)


# ------------------------------------------------------------ model point

def test_system_model_onpolicy_operating_point():
    from repro.core.provisioning import fit_paper_actor_model

    model, _ = fit_paper_actor_model()
    kw = dict(learner_step_s=8.0, batch_size=8, unroll=20,
              queue_capacity=64)
    below = model.onpolicy_point(16, **kw)
    at = model.onpolicy_point(40, **kw)
    above = model.onpolicy_point(256, **kw)
    # below the knee nothing drops and staleness is ~one learner step
    assert below.drop_rate == 0.0 and not below.learner_bound
    assert below.mean_param_lag == pytest.approx(1.0)
    # past the knee: drop rate rises, staleness is the queue depth in
    # batches, and trained frames stop growing (the algorithmic ceiling)
    assert above.learner_bound and above.drop_rate > 0.3
    assert above.mean_param_lag == pytest.approx(64 / 8)
    assert above.frames_trained_per_s == pytest.approx(
        at.frames_trained_per_s, rel=0.2)
    assert above.frames_generated_per_s > at.frames_trained_per_s
    with pytest.raises(ValueError):
        model.onpolicy_point(4, learner_step_s=0.0, batch_size=8, unroll=20)
