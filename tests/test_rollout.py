"""Device-resident rollout subsystem tests: fused env+policy `lax.scan`
unrolls (`repro.rollout`) vs the host loop, frame accounting, learner
integration through `SeedSystem(backend="device")`, and the throughput
acceptance gate (device >= vectorized host at equal (num_actors, E))."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.system import SeedSystem
from repro.envs.alesim import ALESimEnv
from repro.envs.cartpole import CartPoleEnv
from repro.envs.catch import CatchEnv
from repro.rollout import DeviceRolloutEngine, RolloutWorker, action_key


def _random_policy_apply(num_actions):
    def policy_apply(params, core, obs, key):
        return jax.random.randint(key, (obs.shape[0],), 0, num_actions), core
    return policy_apply


def _host_reference(env, E, T, seed, policy_apply, params=None):
    """Step-by-step host loop following the engine's exact key streams:
    lane keys `split(PRNGKey(seed), E)`, action keys `action_key(seed)`
    split once per step."""
    keys = jax.random.split(jax.random.PRNGKey(seed), E)
    vreset = jax.vmap(env.reset)
    vstep = jax.vmap(env.step)
    state, obs = vreset(keys)
    key, core = action_key(seed), None
    out = {"obs": [], "actions": [], "rewards": [], "dones": []}
    for _ in range(T):
        key, sub = jax.random.split(key)
        actions, core = policy_apply(params, core, obs, sub)
        actions = actions.astype(jnp.int32)
        out["obs"].append(np.asarray(obs))
        out["actions"].append(np.asarray(actions))
        state, obs, rewards, dones = vstep(state, actions)
        out["rewards"].append(np.asarray(rewards, np.float32))
        out["dones"].append(np.asarray(dones))
    return {k: np.stack(v) for k, v in out.items()}


# ------------------------------ parity ---------------------------------------

@pytest.mark.parametrize("env_cls", [CartPoleEnv, CatchEnv])
def test_scan_rollout_matches_host_loop(env_cls):
    """Acceptance: the fused scan is step-for-step identical to a host loop
    over the same PRNG keys — same env-state evolution, actions, rewards,
    dones, across auto-reset boundaries."""
    env = env_cls()
    E, T, seed = 4, 50, 11
    policy = _random_policy_apply(env.num_actions)
    eng = DeviceRolloutEngine(env, policy, E, T, seed=seed)
    traj = eng.rollout(None)
    ref = _host_reference(env, E, T, seed, policy)
    np.testing.assert_allclose(traj["obs"], ref["obs"], atol=1e-6)
    np.testing.assert_array_equal(traj["actions"], ref["actions"])
    np.testing.assert_allclose(traj["rewards"], ref["rewards"], atol=1e-6)
    np.testing.assert_array_equal(traj["dones"], ref["dones"])


def test_scan_rollout_resumes_across_calls():
    """Back-to-back rollouts continue the same trajectories: two scans of T
    must equal one host loop of 2T (carry persists between device calls)."""
    env = CatchEnv()
    E, T, seed = 3, 20, 5
    policy = _random_policy_apply(env.num_actions)
    eng = DeviceRolloutEngine(env, policy, E, T, seed=seed)
    t1, t2 = eng.rollout(None), eng.rollout(None)
    ref = _host_reference(env, E, 2 * T, seed, policy)
    np.testing.assert_array_equal(
        np.concatenate([t1["actions"], t2["actions"]]), ref["actions"])
    np.testing.assert_allclose(
        np.concatenate([t1["rewards"], t2["rewards"]]), ref["rewards"],
        atol=1e-6)


def test_engine_with_recurrent_core_state():
    """Core state threads through the scan: an accumulator policy must see
    its own running sum advance T steps within one rollout."""
    env = CatchEnv()
    E, T = 2, 7

    def policy_apply(params, core, obs, key):
        core = core + 1
        return jnp.zeros((obs.shape[0],), jnp.int32), core

    eng = DeviceRolloutEngine(env, policy_apply, E, T,
                              init_core=lambda e: jnp.zeros((e,), jnp.int32))
    eng.rollout(None)
    _, core, _, _ = eng._carry
    np.testing.assert_array_equal(np.asarray(core), np.full((E,), T))
    eng.rollout(None)
    _, core, _, _ = eng._carry
    np.testing.assert_array_equal(np.asarray(core), np.full((E,), 2 * T))


def test_engine_rejects_host_env():
    with pytest.raises(ValueError, match="pure-JAX env"):
        DeviceRolloutEngine(ALESimEnv(frame=8, step_cost=16),
                            _random_policy_apply(18), 2, 4)


# --------------------------- frame accounting --------------------------------

def test_engine_frame_accounting():
    E, T = 4, 12
    eng = DeviceRolloutEngine(CatchEnv, _random_policy_apply(3), E, T)
    for _ in range(3):
        eng.rollout(None)
    assert eng.scans == 3
    assert eng.frames == 3 * T * E


def test_worker_feeds_per_lane_unrolls_and_counts():
    E, T = 3, 6
    eng = DeviceRolloutEngine(CatchEnv, _random_policy_apply(3), E, T, seed=2)
    sunk = []
    w = RolloutWorker(0, eng, sunk.append, lambda: (None, 0))
    w.start()
    import time
    deadline = time.time() + 10.0
    while w.iterations < 2 and time.time() < deadline:
        time.sleep(0.01)
    w.stop()
    w.join()
    assert w.error is None, w.error
    assert w.iterations >= 2
    assert w.frames == w.iterations * T * E
    assert len(sunk) == w.iterations * E        # one unroll per lane per scan
    traj = sunk[0]
    assert traj["obs"].shape[0] == T
    assert traj["actions"].dtype == np.int32
    assert traj["rewards"].dtype == np.float32
    assert traj["dones"].dtype == np.float32
    # Catch episodes are rows-1 steps long, so scans crossed boundaries
    assert w.episodes > 0
    assert len(w.returns) == w.episodes


def test_seed_system_device_frame_accounting():
    E, T, N = 4, 8, 2
    sys_ = SeedSystem(env_factory=CatchEnv, backend="device",
                      policy_apply=_random_policy_apply(3),
                      num_actors=N, unroll=T, envs_per_actor=E)
    sys_.warmup()
    stats = sys_.run(seconds=0.6, with_learner=False)
    assert stats["backend"] == "device"
    assert stats["inference_error"] is None
    # frames = scans x T x E, exactly
    assert stats["env_frames"] == stats["scans"] * T * E
    assert stats["env_frames"] > 0
    for a in sys_.actors:
        assert a.frames == a.iterations * T * E
    # per-lane unrolls of length T landed in replay
    assert len(sys_.replay) > 0
    traj, _, _ = sys_.replay.sample(1)
    assert traj["obs"].shape[1] == T


# ------------------------- learner integration -------------------------------

def test_seed_system_device_with_learner_and_param_lag():
    """The learner publishes versioned params; workers refresh between
    scans and track the on-policy lag."""
    E, T = 4, 8

    def train_step(state, batch):
        return {"params": {"w": state["params"]["w"] + 1.0},
                "step": state.get("step", 0) + 1}, {"loss": np.float32(0.0)}

    sys_ = SeedSystem(env_factory=CatchEnv, backend="device",
                      policy_apply=_random_policy_apply(3),
                      init_params={"w": jnp.zeros(())},
                      num_actors=1, unroll=T, envs_per_actor=E,
                      train_step=train_step, state={"params": {"w": np.zeros(())},
                                                    "step": 0},
                      learner_batch=2, min_replay=2)
    sys_.warmup()
    stats = sys_.run(seconds=1.0)
    assert stats["learner_error"] is None, stats["learner_error"]
    assert stats["learner_steps"] > 0
    assert stats["param_refreshes"] > 0         # workers picked up new params
    assert stats["mean_param_lag"] > 0          # learner advanced between scans
    # all published versions were consumed in order: lag sums to the last
    # version each worker saw
    for a in sys_.actors:
        assert a.param_lag_total == a.param_version


def test_worker_error_is_surfaced():
    def bad_policy(params, core, obs, key):
        raise TypeError("tracer-leak")

    eng = DeviceRolloutEngine(CatchEnv, bad_policy, 2, 4)
    w = RolloutWorker(0, eng, lambda t: None, lambda: (None, 0))
    w.start()
    w.join(timeout=10.0)
    assert w.error is not None and "tracer-leak" in w.error


# --------------------------- throughput gate ---------------------------------

@pytest.mark.skipif(os.environ.get("CI") == "true",
                    reason="wall-clock throughput ratio; shared CI runners "
                           "are too noisy for a hard perf gate")
def test_device_backend_beats_vectorized_host():
    """Acceptance: at equal (num_actors, E) on a pure-JAX env, the fused
    scan must supply at least the vectorized host backend's frames/s — it
    replaces T inference round-trips per unroll with one transfer."""
    N, E, T = 2, 8, 16

    def host_policy(obs, ids):
        return np.random.randint(0, 3, size=(obs.shape[0],))

    def run_host():
        sys_ = SeedSystem(env_factory=CatchEnv, policy_step=host_policy,
                          num_actors=N, unroll=T, envs_per_actor=E,
                          deadline_ms=1.0)
        sys_.warmup()
        return sys_.run(seconds=1.0, with_learner=False)["env_frames_per_s"]

    def run_device():
        sys_ = SeedSystem(env_factory=CatchEnv, backend="device",
                          policy_apply=_random_policy_apply(3),
                          num_actors=N, unroll=T, envs_per_actor=E)
        sys_.warmup()
        return sys_.run(seconds=1.0, with_learner=False)["env_frames_per_s"]

    host = max(run_host(), run_host())
    device = max(run_device(), run_device())
    assert device >= host, (host, device)


# ---------------------- provisioning: device point ---------------------------

def test_system_model_device_operating_point():
    from repro.core.provisioning import fit_paper_actor_model

    model, err = fit_paper_actor_model()
    assert err < 0.05
    dev = model.with_envs(8).with_device()
    # beats both host points at the paper's operating point
    assert float(dev.throughput(40)) > float(model.with_envs(8).throughput(40))
    assert float(dev.throughput(40)) > float(model.throughput(40))
    # not bounded by host threads: scales past the H/t_env ceiling
    cap = model.hw_threads / model.t_env
    assert float(dev.throughput(256)) > cap
    # ... but bounded by scan throughput: asymptote is 1/t_dev1
    assert float(dev.throughput(1e9)) <= 1.0 / dev.t_dev1 + 1e-6


def test_derating_model_envs_axis():
    from repro.core.provisioning import fit_paper_derating

    m = fit_paper_derating()
    assert m.envs_per_actor == 1
    # E=1 calibration unchanged (Fig 4 anchor)
    assert float(m.slowdown(0.5)) == pytest.approx(1.06, abs=1e-6)
    # more lanes per actor -> more overlap -> derating hides better
    assert float(m.with_envs(8).slowdown(0.5)) < float(m.slowdown(0.5))
    ss = [float(m.with_envs(E).slowdown(0.25)) for E in (1, 2, 4, 8)]
    assert all(b < a for a, b in zip(ss, ss[1:]))
    assert float(m.with_envs(8).slowdown(1.0)) == 1.0
