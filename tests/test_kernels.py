"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R

K = jax.random.PRNGKey(7)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


def _fold(x):
    b, s, h, d = x.shape
    return jnp.moveaxis(x, 2, 1).reshape(b * h, s, d)


def _unfold(x, b, h):
    bh, s, d = x.shape
    return jnp.moveaxis(x.reshape(b, h, s, d), 1, 2)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("s,d,dtype", [(128, 64, jnp.float32),
                                       (256, 128, jnp.float32),
                                       (128, 64, jnp.bfloat16)])
@pytest.mark.parametrize("window,softcap", [(0, None), (64, None), (0, 30.0)])
def test_flash_attention(s, d, dtype, window, softcap):
    b, h = 2, 2
    q = _rand(K, (b, s, h, d), dtype)
    k = _rand(jax.random.fold_in(K, 1), (b, s, h, d), dtype)
    v = _rand(jax.random.fold_in(K, 2), (b, s, h, d), dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              softcap=softcap, block_q=64, block_k=64)
    ref = _unfold(R.attention_ref(_fold(q), _fold(k), _fold(v), causal=True,
                                  window=window, softcap=softcap), b, h)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("s,dtype", [(256, jnp.float32), (512, jnp.bfloat16)])
def test_decode_attention(s, dtype):
    b, h, d = 3, 4, 64
    q = _rand(K, (b, h, d), dtype)
    k = _rand(jax.random.fold_in(K, 1), (b, s, h, d), dtype)
    v = _rand(jax.random.fold_in(K, 2), (b, s, h, d), dtype)
    lens = jnp.array([s // 4, s // 2, s], jnp.int32)
    out = ops.decode_attention(q, k, v, lens, block_s=128)
    ref = R.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("s,p,n,chunk", [(128, 16, 32, 32), (256, 32, 16, 64)])
def test_ssd_scan(s, p, n, chunk):
    b, h = 2, 3
    x = _rand(K, (b, s, h, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(_rand(jax.random.fold_in(K, 1), (b, s, h), jnp.float32))
    a = -jnp.exp(_rand(jax.random.fold_in(K, 2), (h,), jnp.float32) * 0.3)
    bb = _rand(jax.random.fold_in(K, 3), (b, s, h, n), jnp.float32) * 0.3
    cc = _rand(jax.random.fold_in(K, 4), (b, s, h, n), jnp.float32) * 0.3
    y = ops.ssd_scan(x, dt, a, bb, cc, chunk=chunk)
    yref, _ = R.ssd_ref(x, dt, a, bb, cc)
    scale = float(jnp.abs(yref).max())
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                               atol=3e-5 * max(scale, 1.0), rtol=1e-4)


@pytest.mark.parametrize("s,w,block_s", [(128, 64, 32), (256, 128, 64)])
def test_rglru_scan(s, w, block_s):
    b = 2
    a = jax.nn.sigmoid(_rand(K, (b, s, w), jnp.float32))
    bb = _rand(jax.random.fold_in(K, 1), (b, s, w), jnp.float32) * 0.1
    h = ops.rglru_scan(a, bb, block_s=block_s)
    href, _ = R.rglru_ref(a, bb)
    np.testing.assert_allclose(np.asarray(h), np.asarray(href),
                               atol=1e-5, rtol=1e-5)


def test_nn_chunked_attention_matches_ref():
    """The jnp chunked path (dry-run default) equals the full-scores ref."""
    from repro.nn.attention import attend_chunked, attend_ref
    b, s, hq, hk, d = 2, 96, 4, 2, 32
    q = _rand(K, (b, s, hq, d), jnp.float32)
    k = _rand(jax.random.fold_in(K, 1), (b, s, hk, d), jnp.float32)
    v = _rand(jax.random.fold_in(K, 2), (b, s, hk, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    out = attend_chunked(q, k, v, pos, pos, scale=0.2, chunk=32)
    ke = jnp.repeat(k, 2, axis=2)
    ve = jnp.repeat(v, 2, axis=2)
    ref = attend_ref(q, ke, ve, pos, pos, scale=0.2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_nn_ssd_chunked_matches_ref():
    from repro.nn.ssd import ssd_chunked
    b, s, h, p, n = 2, 64, 2, 8, 16
    x = _rand(K, (b, s, h, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(_rand(jax.random.fold_in(K, 1), (b, s, h), jnp.float32))
    a = -jnp.exp(_rand(jax.random.fold_in(K, 2), (h,), jnp.float32) * 0.3)
    bb = _rand(jax.random.fold_in(K, 3), (b, s, 1, n), jnp.float32) * 0.3
    cc = _rand(jax.random.fold_in(K, 4), (b, s, 1, n), jnp.float32) * 0.3
    y, st = ssd_chunked(x, dt, a, bb, cc, chunk=16)
    yref, stref = R.ssd_ref(x, dt, a, jnp.repeat(bb, h, 2), jnp.repeat(cc, h, 2))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=3e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(stref), atol=3e-5,
                               rtol=1e-4)
