"""Live ops plane tests: Prometheus render/parse/validate round-trips,
heartbeat verdicts, watchdog transitions, flight-recorder bundles, the
continuous auditor, and the HTTP endpoints against live `SeedSystem`s.

The load-bearing ones are the e2e promises from the ops-plane design:
a `/metrics` scrape of a live system must expose a frame ledger that is
conserved WITHIN the scrape and matches `throughput()` exactly; a
deliberately wedged replica must flip `/healthz` to ``degraded`` naming
that replica within 2 s and leave a postmortem bundle while the OTHER
replica keeps serving; and a full vtrace socket training run must pass
the continuous invariant auditor with zero violations.
"""

import json
import os
import threading
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.core.system import SeedSystem
from repro.envs.catch import CatchEnv
from repro.onpolicy import VTraceLearner, mlp_actor_critic
from repro.optim import adamw
from repro.telemetry import (FlightRecorder, HeartbeatRegistry,
                             InvariantAuditor, MetricsRegistry, Telemetry,
                             UtilizationSampler, Watchdog, parse_prometheus,
                             render_prometheus, sanitize_metric_name,
                             validate_prometheus)
from repro.telemetry.ops import value_of
from repro.telemetry.sink import METRICS_SCHEMA_VERSION

OBS_DIM = 50          # CatchEnv() default 10x5


def _http_get(url, timeout=5.0):
    """(status, body) — a 503 /healthz still carries a JSON body."""
    try:
        with urllib.request.urlopen(url, timeout=timeout) as resp:
            return resp.status, resp.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


# ------------------------------------------------- prometheus exposition

def test_sanitize_metric_name():
    assert sanitize_metric_name("onpolicy/frames_generated") == \
        "onpolicy_frames_generated"
    assert sanitize_metric_name("inference/r0/batches") == \
        "inference_r0_batches"
    assert sanitize_metric_name("9lives") == "_9lives"
    assert sanitize_metric_name("ok_name:x") == "ok_name:x"


def test_render_parse_roundtrip_is_exact_for_ledger_ints():
    """Counters are the conserved frame ledger: a scrape must round-trip
    them EXACTLY (no float formatting drift), including past 2^31."""
    reg = MetricsRegistry()
    reg.counter("onpolicy/frames_generated").add(12_345_678_901)
    reg.gauge("onpolicy/frames_pending").set(7)
    h = reg.histogram("learner/train_s")
    for v in (1e-4, 2e-4, 8e-3):
        h.record(v)
    text = render_prometheus(reg.snapshot(),
                             extra_gauges={"inference/num_slots": 4})
    assert validate_prometheus(text) == []
    parsed = parse_prometheus(text)
    assert value_of(parsed, "onpolicy_frames_generated") == 12_345_678_901
    assert value_of(parsed, "onpolicy_frames_pending") == 7
    assert value_of(parsed, "inference_num_slots") == 4
    assert parsed["types"]["onpolicy_frames_generated"] == "counter"
    assert parsed["types"]["learner_train_s"] == "histogram"
    assert value_of(parsed, "learner_train_s_count") == 3
    assert value_of(parsed, "learner_train_s_sum") == pytest.approx(83e-4)


def test_histogram_buckets_are_cumulative_with_inf_terminal():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    for v in (1e-6, 1e-6, 1e-3):
        h.record(v)
    text = render_prometheus(reg.snapshot())
    assert validate_prometheus(text) == []
    buckets = [(labels.get("le"), v)
               for name, labels, v in parse_prometheus(text)["samples"]
               if name == "lat_bucket"]
    assert buckets[-1] == ("+Inf", 3)
    counts = [v for _, v in buckets]
    assert counts == sorted(counts)          # cumulative => non-decreasing


def test_validator_catches_broken_expositions():
    assert validate_prometheus("totally not prometheus{")
    # sample without a TYPE declaration
    assert any("TYPE" in v for v in validate_prometheus("orphan 1\n"))
    # non-monotone cumulative buckets
    bad = ("# TYPE h histogram\n"
           'h_bucket{le="1"} 5\n'
           'h_bucket{le="2"} 3\n'
           'h_bucket{le="+Inf"} 5\n'
           "h_sum 1\nh_count 5\n")
    assert any("monotonic" in v or "cumulative" in v
               for v in validate_prometheus(bad))
    # +Inf bucket disagrees with _count
    bad2 = ("# TYPE h histogram\n"
            'h_bucket{le="+Inf"} 4\n'
            "h_sum 1\nh_count 5\n")
    assert validate_prometheus(bad2)


# -------------------------------------------------- heartbeats + watchdog

def test_heartbeat_verdict_transitions():
    reg = HeartbeatRegistry()
    reg.register("fast", stale_after_s=0.05)
    reg.register("slow", stale_after_s=60.0)
    reg.register("info", stale_after_s=None)      # never flips the verdict
    reg.beat("fast")
    reg.beat("slow")
    assert reg.report().verdict == "healthy"
    time.sleep(0.08)
    rep = reg.report()                      # fast stale, slow still fine
    assert rep.verdict == "degraded"
    assert rep.stale == ["fast"]
    assert rep.components["info"]["stale"] is False
    reg.unregister("slow")                  # every remaining watched stale
    assert reg.report().verdict == "stalled"
    reg.unregister("fast")                  # info alone: healthy, not dead
    assert reg.report().verdict == "healthy"


def test_beat_auto_registers_under_default_deadline():
    """The actor-host relay beats names it never registered; they must
    come out watched (default deadline), not invisible."""
    reg = HeartbeatRegistry(default_stale_after_s=0.05)
    reg.beat("actor-host-0")
    rep = reg.report()
    assert rep.components["actor-host-0"]["stale_after_s"] == 0.05
    time.sleep(0.08)
    assert reg.report().verdict == "stalled"


def test_health_events_force_degraded_then_expire():
    reg = HeartbeatRegistry(event_window_s=0.1)
    reg.register("loop", stale_after_s=60.0)
    reg.beat("loop")
    reg.event("auditor", "ledger not conserved")
    rep = reg.report()
    assert rep.verdict == "degraded"
    assert rep.events[0]["message"] == "ledger not conserved"
    time.sleep(0.15)
    assert reg.report().verdict == "healthy"    # event aged out


def test_watchdog_fires_once_per_transition():
    reg = HeartbeatRegistry()
    reg.register("comp", stale_after_s=0.05)
    reg.beat("comp")
    fired = []
    dog = Watchdog(reg, on_unhealthy=fired.append)
    assert dog.check().verdict == "healthy"
    assert fired == []
    time.sleep(0.08)
    assert dog.check().verdict != "healthy"
    assert len(fired) == 1
    dog.check()                             # still unhealthy: no refire
    assert len(fired) == 1
    assert dog.transitions == 1
    reg.beat("comp")
    assert dog.check().verdict == "healthy"
    assert dog.latest.verdict == "healthy"


# ------------------------------------------------------- flight recorder

def test_flight_recorder_bundle_contents(tmp_path):
    rec = FlightRecorder(out_dir=str(tmp_path))
    rec.add_provider("metrics", lambda: {"counters": {"x": 1}})
    rec.set_trace_source(lambda: [{"name": "span", "ph": "X", "pid": 1,
                                   "tid": 1, "ts": 0, "dur": 1}],
                         lambda evs: {"traceEvents": evs})
    path = rec.trigger("unit_test", detail="deliberate")
    assert path is not None and os.path.isdir(path)
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    assert manifest["reason"] == "unit_test"
    assert manifest["detail"] == "deliberate"
    stacks = open(os.path.join(path, "stacks.txt")).read()
    assert threading.current_thread().name in stacks
    assert json.load(open(os.path.join(path, "metrics.json"))) == \
        {"counters": {"x": 1}}
    trace = json.load(open(os.path.join(path, "trace.json")))
    assert trace["traceEvents"][0]["name"] == "span"
    assert rec.bundles == [path]
    # no half-written staging dirs left behind
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_flight_recorder_cooldown_and_cap(tmp_path):
    rec = FlightRecorder(out_dir=str(tmp_path), max_bundles=3,
                         per_reason_cooldown_s=60.0)
    assert rec.trigger("wedge") is not None
    assert rec.trigger("wedge") is None          # same reason: cooldown
    assert rec.trigger("other") is not None      # different reason: fine
    assert rec.trigger("third") is not None
    assert rec.trigger("fourth") is None         # global cap
    assert len(rec.bundles) == 3
    assert rec.dropped == 2


def test_flight_recorder_never_raises(tmp_path):
    rec = FlightRecorder(out_dir=str(tmp_path))
    rec.add_provider("broken", lambda: 1 / 0)
    rec.set_trace_source(lambda: 1 / 0, lambda evs: 1 / 0)
    path = rec.trigger("resilience")
    assert path is not None                      # bundle still lands
    assert os.path.exists(os.path.join(path, "stacks.txt"))
    disabled = FlightRecorder(out_dir=str(tmp_path), enabled=False)
    assert disabled.trigger("noop") is None


# ------------------------------------------------------ invariant auditor

def test_auditor_reports_each_violation_once():
    aud = InvariantAuditor()
    state = {"bad": False}
    aud.add_check("ledger", lambda: ["broken"] if state["bad"] else [])
    assert aud.tick() == []
    state["bad"] = True
    new = aud.tick()
    assert new == ["broken"]
    assert aud.tick() == []                      # deduped, still recorded
    assert len(aud.violations) == 1
    assert aud.violations[0]["check"] == "ledger"


def test_auditor_counter_monotonicity_and_raising_check():
    aud = InvariantAuditor()
    reg = MetricsRegistry()
    c = reg.counter("frames")
    c.add(10)
    aud.watch_registry("main", reg)
    assert aud.tick() == []
    with reg.lock:
        c.value -= 5                             # counters must never go back
    new = aud.tick()
    assert len(new) == 1 and "frames" in new[0]
    aud.add_check("explodes", lambda: 1 / 0)
    new = aud.tick()
    assert len(new) == 1 and "raised" in new[0]


def test_auditor_escalates_to_health_and_flightrec(tmp_path):
    tel = Telemetry(process_name="learner", out_dir=str(tmp_path))
    tel.auditor.add_check("always", lambda: ["invariant broken"])
    tel.auditor.tick()
    assert tel.health.report().verdict == "degraded"
    assert len(tel.flightrec.bundles) == 1
    assert "audit_violation" in tel.flightrec.bundles[0]


# --------------------------------------------- satellite fixes (1 and 2)

def test_sampler_survives_vanished_pid(caplog):
    """A reaped actor-host pid must be skipped (logged once), not raise
    and kill the sampler thread."""
    reg = MetricsRegistry()
    s = UtilizationSampler(reg)
    s.watch("self", os.getpid())
    s.watch("ghost", 2 ** 22 + 12345)            # never a live pid
    with caplog.at_level("WARNING", logger="repro.telemetry.sampler"):
        for _ in range(3):
            s.sample()                           # must not raise
    vanished_logs = [r for r in caplog.records if "ghost" in r.getMessage()]
    assert len(vanished_logs) == 1               # logged ONCE, not per tick
    totals = s.cpu_totals()
    assert "self" in totals                      # live pid still tracked
    s.watch("ghost", os.getpid())                # re-watch revives the name
    s.sample()
    assert "ghost" in s.cpu_totals()


def test_sink_dump_is_atomic_and_stamped(tmp_path):
    tel = Telemetry(process_name="learner", out_dir=str(tmp_path))
    tel.metrics.counter("x").add(3)
    tel.sampler.sample()
    paths = tel.dump()
    lines = [json.loads(ln) for ln in open(paths["metrics"]) if ln.strip()]
    assert lines
    for i, line in enumerate(lines):
        assert line["schema"] == METRICS_SCHEMA_VERSION
        assert line["tick"] == i                 # monotonic tick index
    json.load(open(paths["trace"]))              # valid JSON, fully written
    leftovers = [f for f in os.listdir(tmp_path) if ".tmp." in f]
    assert leftovers == []                       # os.replace cleaned up


# ------------------------------------------------- live system endpoints

def _vtrace_system(tmp_path, **kw):
    init_fn, apply_fn = mlp_actor_critic(OBS_DIM, 3)
    vl = VTraceLearner(apply_fn, adamw(1e-3))
    params = init_fn(jax.random.PRNGKey(0))
    state = vl.init_state(params)
    policy = vl.sampling_policy(params)
    for lanes in (4, 8):                         # pre-compile
        policy(np.zeros((lanes, OBS_DIM), np.float32), None)
    vl.warmup(state, batch_size=4, unroll=8, obs_shape=(OBS_DIM,))
    tel = Telemetry(process_name="learner", out_dir=str(tmp_path))
    return SeedSystem(env_factory=CatchEnv, policy_step=policy,
                      num_actors=2, unroll=8, envs_per_actor=4,
                      deadline_ms=1.0, algo="vtrace",
                      train_step=vl.train_step, state=state,
                      learner_batch=4, policy_publish=policy.publish,
                      telemetry=tel, ops_port=0, **kw)


def test_metrics_scrape_matches_conserved_ledger_exactly(tmp_path):
    """Acceptance: GET /metrics on a live SeedSystem(ops_port=0) returns
    parseable Prometheus text whose frame counters match the conserved
    ledger in throughput() EXACTLY (one atomic stats() call per scrape)."""
    sys_ = _vtrace_system(tmp_path, max_param_lag=50)
    sys_.warmup()
    host, port = sys_.ops_address
    base = f"http://{host}:{port}"
    stats = sys_.run(seconds=1.2)
    assert stats["ops_address"] == f"{host}:{port}"
    try:
        # the ops server outlives run() so the final quiescent ledger is
        # still scrapeable
        status, text = _http_get(base + "/metrics")
        assert status == 200
        assert validate_prometheus(text) == []
        parsed = parse_prometheus(text)
        onp = stats["onpolicy"]
        for key in ("frames_generated", "frames_trained", "frames_dropped",
                    "frames_pending", "unrolls_trained", "capacity"):
            got = value_of(parsed, f"onpolicy_{key}")
            assert got == onp[key], (key, got, onp[key])
        gen = value_of(parsed, "onpolicy_frames_generated")
        assert gen == (value_of(parsed, "onpolicy_frames_trained")
                       + value_of(parsed, "onpolicy_frames_dropped")
                       + value_of(parsed, "onpolicy_frames_pending"))
        assert value_of(parsed, "inference_num_slots") == \
            sys_.server.num_slots
        # /varz is the autoscaler's document: stats + bottleneck + health
        status, vz = _http_get(base + "/varz")
        assert status == 200
        varz = json.loads(vz)
        assert varz["stats"]["onpolicy"]["frames_generated"] == \
            onp["frames_generated"]
        assert "health" in varz
        # post-run every loop unregistered cleanly: /healthz reads healthy
        status, hz = _http_get(base + "/healthz")
        assert status == 200
        assert json.loads(hz)["verdict"] == "healthy"
        # /trace serves the span rings on demand
        status, tr = _http_get(base + "/trace")
        assert status == 200
        assert isinstance(json.loads(tr)["traceEvents"], list)
        status, _ = _http_get(base + "/nonsense")
        assert status == 404
    finally:
        sys_.stop_ops()
    assert sys_.ops_address is None


# --------------------------------------- satellite 3: the wedge e2e test

_WEDGE = {"on": False, "release": threading.Event()}


def _wedgeable_policy(obs, ids):
    if _WEDGE["on"] and \
            threading.current_thread().name == "inference-replica-1":
        _WEDGE["release"].wait(timeout=30.0)
    flat = np.abs(obs.reshape(obs.shape[0], -1))
    return (flat.sum(axis=1) * 997.0).astype(np.int64) % CatchEnv.num_actions


def test_wedged_replica_flips_healthz_and_writes_postmortem(tmp_path):
    """Acceptance: wedge ONE replica mid-run; /healthz must flip to
    ``degraded`` naming that replica within 2 s, a postmortem bundle must
    appear, and the OTHER replica must keep serving."""
    _WEDGE["on"] = False
    _WEDGE["release"].clear()
    tel = Telemetry(process_name="learner", out_dir=str(tmp_path))
    sys_ = SeedSystem(env_factory=CatchEnv, policy_step=_wedgeable_policy,
                      num_actors=2, unroll=8, envs_per_actor=2,
                      deadline_ms=1.0, num_replicas=2, telemetry=tel,
                      ops_port=0)
    host, port = sys_.ops_address
    base = f"http://{host}:{port}"
    sys_.warmup()
    runner = threading.Thread(
        target=lambda: sys_.run(seconds=8.0, with_learner=False),
        daemon=True)
    runner.start()
    try:
        # let both replicas serve real traffic first
        deadline = time.perf_counter() + 5.0
        while time.perf_counter() < deadline:
            c = tel.metrics.snapshot()["counters"]
            if c.get("inference/r0/batches", 0) > 0 and \
                    c.get("inference/r1/batches", 0) > 0:
                break
            time.sleep(0.05)
        else:
            pytest.fail("replicas never started serving")
        status, hz = _http_get(base + "/healthz")
        assert status == 200 and json.loads(hz)["verdict"] == "healthy"

        wedged_at = time.perf_counter()
        _WEDGE["on"] = True
        flipped = None
        while time.perf_counter() - wedged_at < 4.0:
            status, hz = _http_get(base + "/healthz")
            rep = json.loads(hz)
            if status == 503 and rep["verdict"] == "degraded" and \
                    "inference/replica1" in rep["stale"]:
                flipped = time.perf_counter() - wedged_at
                break
            time.sleep(0.1)
        assert flipped is not None, f"never flipped: {rep}"
        assert flipped <= 2.0, f"flip took {flipped:.2f}s (promise is 2s)"
        # the blame is isolated: replica 0 and both actors stay un-stale
        assert "inference/replica0" not in rep["stale"]
        assert not any(s.startswith("actor/") for s in rep["stale"])

        # the watchdog transition filed a postmortem bundle
        deadline = time.perf_counter() + 3.0
        while time.perf_counter() < deadline and not tel.flightrec.bundles:
            time.sleep(0.1)
        assert tel.flightrec.bundles, "no postmortem bundle appeared"
        bundle = tel.flightrec.bundles[0]
        assert "watchdog_degraded" in bundle
        stacks = open(os.path.join(bundle, "stacks.txt")).read()
        assert "inference-replica-1" in stacks   # the wedged thread's stack
        assert os.path.exists(os.path.join(bundle, "trace.json"))
        assert os.path.exists(os.path.join(bundle, "health.json"))

        # the OTHER replica keeps serving: frames still flow through r0
        before = tel.metrics.snapshot()["counters"]["inference/r0/batches"]
        time.sleep(0.6)
        after = tel.metrics.snapshot()["counters"]["inference/r0/batches"]
        assert after > before, "replica 0 stopped serving during the wedge"
    finally:
        _WEDGE["release"].set()
        _WEDGE["on"] = False
        runner.join(timeout=15.0)
        sys_.stop_ops()
    assert not runner.is_alive()


# ------------------------------- acceptance: continuous auditor, socket e2e

def test_auditor_zero_violations_full_vtrace_socket_run(tmp_path):
    """Acceptance: the continuous auditor ticks through a full vtrace
    socket-backend training e2e with ZERO violations, and the actor-host
    children's piggybacked heartbeats reach the parent registry."""
    sys_ = _vtrace_system(tmp_path, transport="socket", num_actor_hosts=1,
                          max_param_lag=100)
    tel = sys_.telemetry
    host, port = sys_.ops_address
    seen_components = set()
    done = threading.Event()

    def _poll_components():
        while not done.wait(0.25):
            try:
                _, hz = _http_get(f"http://{host}:{port}/healthz")
                seen_components.update(json.loads(hz)["components"])
            except Exception:
                pass

    poller = threading.Thread(target=_poll_components, daemon=True)
    poller.start()
    try:
        stats = sys_.run(seconds=2.0)
    finally:
        done.set()
        poller.join(timeout=5.0)
        sys_.stop_ops()
    assert stats["host_errors"] == [], stats["host_errors"]
    assert stats["learner_steps"] > 0
    onp = stats["onpolicy"]
    assert onp["frames_generated"] == (onp["frames_trained"]
                                       + onp["frames_dropped"]
                                       + onp["frames_pending"])
    assert tel.auditor.ticks > 0, "auditor never ticked during the run"
    assert tel.auditor.violations == [], tel.auditor.violations
    # the mid-run /healthz view saw the whole plane, including the child
    # process heartbeats relayed over the result queue
    assert "learner" in seen_components
    assert any(c.startswith("inference/replica") for c in seen_components)
    assert "actor-host-0" in seen_components, sorted(seen_components)
