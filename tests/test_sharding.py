"""Sharded-inference-plane tests: slot stickiness under lane sharding,
bit-identical `num_replicas=1` parity with the single-server semantics,
multi-gateway end-to-end, engine-sharded device scans, validation, and
the (loose, best-of-5) sharded throughput gate.

The parity test is the load-bearing one: with `num_replicas=1` the
refactored server must produce byte-for-byte the same per-lane unroll
stream as the pre-sharding single-loop server — which, under a
deterministic slot-order-independent policy, equals a direct host loop
over the same seeded vector env. Sharding must then change NOTHING about
trajectories (only which thread computes them), so `num_replicas=2` is
held to the same reference.
"""

import os
import threading
import time

import numpy as np
import pytest

from repro.core.actor import Actor
from repro.core.inference import InferenceServer
from repro.core.system import SeedSystem
from repro.envs.catch import CatchEnv
from repro.envs.vector import make_vector_env
from repro.launch.actor_host import ActorHostPool


def det_policy(obs, ids):
    """Deterministic and slot-order independent, so batching/arrival order
    (which legitimately differs across replicas) cannot change actions."""
    flat = np.abs(obs.reshape(obs.shape[0], -1))
    return (flat.sum(axis=1) * 997.0).astype(np.int64) % CatchEnv.num_actions


# ------------------------------------------------------------ validation

def test_num_replicas_validation_is_a_clear_valueerror():
    with pytest.raises(ValueError, match="num_replicas"):
        InferenceServer(det_policy, max_batch=2, num_replicas=3)
    with pytest.raises(ValueError, match="num_replicas"):
        InferenceServer(det_policy, max_batch=4, num_replicas=0)
    with pytest.raises(ValueError, match="num_replicas"):
        SeedSystem(env_factory=CatchEnv, policy_step=det_policy,
                   num_actors=1, unroll=4, envs_per_actor=2,
                   inference_batch=2, num_replicas=4)
    # the device backend has no central server to shard
    with pytest.raises(ValueError, match="num_replicas"):
        SeedSystem(env_factory=CatchEnv, backend="device",
                   policy_apply=lambda p, c, o, k: (o, c),
                   num_actors=1, unroll=4, num_replicas=2)


def test_multi_gateway_fixed_port_is_a_clear_valueerror():
    # two gateways cannot bind one fixed port; must fail at construction,
    # not leak a half-started plane from inside run()
    with pytest.raises(ValueError, match="gateway_port"):
        SeedSystem(env_factory=CatchEnv, policy_step=det_policy,
                   num_actors=2, unroll=4, transport="socket",
                   num_actor_hosts=2, num_gateways=2, gateway_port=5555)


def test_num_gateways_validation_is_a_clear_valueerror():
    with pytest.raises(ValueError, match="num_gateways"):
        SeedSystem(env_factory=CatchEnv, policy_step=det_policy,
                   num_actors=2, unroll=4, transport="socket",
                   num_actor_hosts=1, num_gateways=2)
    with pytest.raises(ValueError, match="num_gateways"):
        SeedSystem(env_factory=CatchEnv, policy_step=det_policy,
                   num_actors=2, unroll=4, num_gateways=2)  # inproc
    with pytest.raises(ValueError, match="num_gateways"):
        SeedSystem(env_factory=CatchEnv, policy_step=det_policy,
                   num_actors=2, unroll=4, transport="socket",
                   num_gateways=0)


def test_engine_shards_validation_is_a_clear_valueerror():
    from repro.rollout import ShardedRolloutEngine

    def pol(params, core, obs, key):
        return np.zeros(obs.shape[0]), core

    with pytest.raises(ValueError, match="num_shards"):
        ShardedRolloutEngine(CatchEnv, pol, 2, 4, num_shards=3)
    with pytest.raises(ValueError, match="num_shards"):
        ShardedRolloutEngine(CatchEnv, pol, 2, 4, num_shards=0)
    with pytest.raises(ValueError, match="engine_shards"):
        SeedSystem(env_factory=CatchEnv, policy_step=det_policy,
                   num_actors=1, unroll=4, engine_shards=2)  # host backend


def test_model_with_sharded_validation():
    from repro.core.provisioning import fit_paper_actor_model

    model, _ = fit_paper_actor_model()
    with pytest.raises(ValueError, match="n_replicas"):
        model.with_sharded(0)
    with pytest.raises(ValueError, match="n_replicas"):
        model.with_sharded(model.batch_cap + 1)
    # mirrors the runtime: no central inference on the device point
    with pytest.raises(ValueError, match="with_sharded"):
        model.with_device().with_sharded(2)


def test_wire_compression_validation_is_a_clear_valueerror():
    with pytest.raises(ValueError, match="wire_compression"):
        SeedSystem(env_factory=CatchEnv, policy_step=det_policy,
                   num_actors=2, unroll=4, wire_compression=True)  # inproc


# -------------------------------------------------------- slot stickiness

def test_lane_slots_never_migrate_replicas():
    """THE sharding invariant: a lane's (actor_id, env_id) recurrent slot
    is only ever presented to ONE replica's policy forward, across many
    interleaved requests from many actors."""
    seen = {}
    lock = threading.Lock()

    def recording_policy(obs, ids):
        name = threading.current_thread().name
        with lock:
            for slot in np.asarray(ids):
                seen.setdefault(int(slot), set()).add(name)
        return det_policy(obs, ids)

    srv = InferenceServer(recording_policy, max_batch=12, deadline_ms=2.0,
                          num_replicas=3)
    srv.start()
    try:
        obs = np.random.rand(2, 50).astype(np.float32)
        for round_ in range(4):
            replies = [srv.submit_batch(aid, obs) for aid in range(6)]
            for r in replies:
                out = r.get(timeout=5.0)
                assert out.shape == (2,), out
    finally:
        srv.stop()
    assert srv.error is None, srv.error
    # every slot pinned to exactly one replica thread, and the routing
    # actually sharded (more than one replica saw traffic)
    assert seen and all(len(names) == 1 for names in seen.values()), seen
    assert len({next(iter(v)) for v in seen.values()}) > 1
    assert srv.num_slots == 12          # 6 actors x 2 lanes, no duplicates


def test_replica_stats_are_per_replica_and_aggregate():
    srv = InferenceServer(det_policy, max_batch=8, deadline_ms=1.0,
                          num_replicas=2)
    srv.start()
    try:
        obs = np.random.rand(2, 50).astype(np.float32)
        for aid in (0, 1, 2, 3):
            srv.submit_batch(aid, obs).get(timeout=5.0)
    finally:
        srv.stop()
    per = srv.per_replica_stats()
    assert [p["replica"] for p in per] == [0, 1]
    assert all(p["lane_budget"] == 4 for p in per)      # ceil(8 / 2)
    # aggregate == sum of shards, and both shards actually served lanes
    assert sum(p["requests"] for p in per) == srv.stats["requests"] == 8
    assert all(p["requests"] == 4 for p in per)
    d = srv.derived_stats()
    assert d["mean_lanes_per_rpc"] == pytest.approx(2.0)


# ----------------------------------------------------------------- parity

def _reference_unrolls(num_envs, unroll, n_traj, actor_id=0):
    """The pre-PR single-server semantics, computed directly: a host loop
    over the same seeded vector env under the same deterministic policy.
    (The single-loop server produced exactly this stream — asserted by
    the pre-existing transport parity suite.)"""
    vec = make_vector_env(CatchEnv, num_envs, seed=actor_id)
    obs = vec.reset()
    out, buf = [], {"obs": [], "actions": [], "rewards": [], "dones": []}
    while len(out) < n_traj:
        actions = det_policy(obs, None)
        nobs, rewards, dones = vec.step(actions)
        buf["obs"].append(obs)
        buf["actions"].append(actions)
        buf["rewards"].append(rewards)
        buf["dones"].append(dones)
        if len(buf["actions"]) >= unroll:
            stacked = {k: np.stack(v) for k, v in buf.items()}
            for lane in range(num_envs):
                out.append({
                    "obs": stacked["obs"][:, lane],
                    "actions": stacked["actions"][:, lane].astype(np.int32),
                    "rewards": stacked["rewards"][:, lane].astype(np.float32),
                    "dones": stacked["dones"][:, lane].astype(np.float32),
                })
            buf = {"obs": [], "actions": [], "rewards": [], "dones": []}
        obs = nobs
    return out[:n_traj]


def _run_replicated_rollout(num_replicas, n_traj, num_envs=3, unroll=4):
    srv = InferenceServer(det_policy, max_batch=max(3, num_replicas),
                          deadline_ms=2.0, num_replicas=num_replicas)
    trajs = []
    actor = Actor(0, CatchEnv, srv, lambda t: trajs.append(t),
                  unroll=unroll, num_envs=num_envs)
    srv.start()
    actor.start()
    deadline = time.perf_counter() + 30.0
    while len(trajs) < n_traj and time.perf_counter() < deadline:
        time.sleep(0.01)
    actor.stop()
    srv.stop()
    actor.join()
    assert actor.error is None, actor.error
    assert len(trajs) >= n_traj, \
        f"replicated rollout produced {len(trajs)} < {n_traj} unrolls"
    return trajs[:n_traj]


@pytest.mark.parametrize("num_replicas", [1, 2])
def test_replicated_rollout_bit_identical_to_single_server_reference(
        num_replicas):
    """`num_replicas=1` must be the pre-PR single-server path bit-for-bit,
    and sharding must not change trajectories at all — both compared
    against the directly-computed reference stream under fixed seeds."""
    n = 6
    got = _run_replicated_rollout(num_replicas, n)
    ref = _reference_unrolls(3, 4, n)
    for i, (ta, tb) in enumerate(zip(got, ref)):
        assert sorted(ta) == sorted(tb)
        for k in ta:
            va, vb = np.asarray(ta[k]), np.asarray(tb[k])
            assert va.dtype == vb.dtype, (num_replicas, i, k)
            assert np.array_equal(va, vb), \
                f"replicas={num_replicas} unroll {i} key {k} diverged"


# ------------------------------------------------- multi-gateway e2e

def test_multi_gateway_two_hosts_end_to_end():
    """2 gateways x 2 actor hosts through `SeedSystem`: hosts hash across
    gateway addresses, frames flow through BOTH accept loops, trajectory
    frames from both gateways land in the shared replay sink, and the
    run is error-free."""
    sys_ = SeedSystem(env_factory=CatchEnv, policy_step=det_policy,
                      num_actors=2, unroll=4, envs_per_actor=2,
                      deadline_ms=1.0, transport="socket",
                      num_actor_hosts=2, num_gateways=2, num_replicas=2,
                      wire_compression=True)
    stats = sys_.run(seconds=1.0, with_learner=False)
    assert stats["inference_error"] is None, stats["inference_error"]
    assert stats["host_errors"] == []
    # wire_compression threaded through the spawned hosts: each actor
    # connection HELLOed its gateway (Catch obs are float32, so no RLE
    # frames follow — the uint8 compression itself is unit-tested)
    assert sum(gw.stats["hello_frames"] for gw in sys_.gateways) == 2
    assert stats["num_gateways"] == 2
    assert stats["num_replicas"] == 2
    # host h dialed gateway h % 2 -> exactly one host (of 1 actor each,
    # one SyncSocketTransport per actor) behind each gateway
    assert stats["per_gateway_connections"] == [1, 1]
    assert stats["env_frames"] > 0
    assert stats["gateway_traj_frames"] > 0
    assert len(sys_.replay) > 0, "trajectories did not reach replay"
    # both replicas served lanes (actor 0 -> replica 0, actor 1 -> 1)
    assert all(n > 0 for n in stats["replica_lanes"]), stats["replica_lanes"]


def test_multi_gateway_socket_parity_with_inproc():
    """The transport parity contract survives sharding: a 2-gateway,
    2-host, 2-replica socket rollout produces the same per-lane unroll
    multiset as the in-proc reference (frames arrive interleaved across
    gateways, so compare as multisets keyed by content hash)."""
    n = 4
    ref = _reference_unrolls(2, 4, n, actor_id=0) + \
        _reference_unrolls(2, 4, n, actor_id=1)

    srv = InferenceServer(det_policy, max_batch=4, deadline_ms=2.0,
                          num_replicas=2)
    trajs = []
    lock = threading.Lock()

    def sink(t):
        with lock:
            trajs.append(t)

    from repro.transport.socket import InferenceGateway
    gws = [InferenceGateway(srv, sink=sink) for _ in range(2)]
    srv.start()
    addrs = [gw.start() for gw in gws]
    pool = ActorHostPool(CatchEnv, num_actors=2, envs_per_actor=2,
                         unroll=4, num_hosts=2)
    stats = pool.run(addrs, seconds=2.5)
    for gw in reversed(gws):
        gw.stop()
    srv.stop()
    assert all(s["error"] is None for s in stats), stats
    assert len(trajs) >= len(ref), (len(trajs), len(ref))

    def key(t):
        return tuple(sorted((k, np.asarray(v).tobytes())
                            for k, v in t.items()))

    got_keys = {key(t) for t in trajs}
    for i, r in enumerate(ref):
        assert key(r) in got_keys, f"reference unroll {i} missing"


# --------------------------------------------- engine-sharded device scans

def test_sharded_engine_frame_accounting_and_schema():
    import jax

    from repro.rollout import RolloutWorker, ShardedRolloutEngine

    def pol(params, core, obs, key):
        return jax.random.randint(key, (obs.shape[0],), 0,
                                  CatchEnv.num_actions), core

    E, T = 5, 6                      # uneven split: shards of 3 and 2 lanes
    eng = ShardedRolloutEngine(CatchEnv, pol, E, T, num_shards=2, seed=0)
    assert [e.num_envs for e in eng.engines] == [3, 2]
    assert all(e.device is not None for e in eng.engines)
    traj = eng.rollout(None)
    assert traj["obs"].shape[:2] == (T, E)
    assert traj["actions"].shape == (T, E)
    assert eng.scans == 1 and eng.shard_scans == 2
    assert eng.frames == T * E
    # rides RolloutWorker unchanged
    sunk = []
    w = RolloutWorker(0, eng, sunk.append, lambda: (None, 0))
    w.start()
    deadline = time.time() + 15.0
    while w.iterations < 3 and time.time() < deadline:
        time.sleep(0.01)
    w.stop()
    w.join()
    assert w.error is None, w.error
    assert w.frames == w.iterations * T * E
    assert len(sunk) == (w.iterations - 1) * E  # first rollout above sank none


def test_seed_system_engine_sharded_device_backend():
    import jax

    def pol(params, core, obs, key):
        return jax.random.randint(key, (obs.shape[0],), 0,
                                  CatchEnv.num_actions), core

    E, T = 4, 8
    sys_ = SeedSystem(env_factory=CatchEnv, backend="device",
                      policy_apply=pol, num_actors=2, unroll=T,
                      envs_per_actor=E, engine_shards=2)
    sys_.warmup()
    stats = sys_.run(seconds=0.6, with_learner=False)
    assert stats["inference_error"] is None, stats["inference_error"]
    assert stats["engine_shards"] == 2
    assert stats["env_frames"] == stats["scans"] * T * E
    assert stats["env_frames"] > 0
    assert len(sys_.replay) > 0
    traj, _, _ = sys_.replay.sample(1)
    assert traj["obs"].shape[1] == T


# -------------------------------------------------------- throughput gate

@pytest.mark.skipif(os.environ.get("CI") == "true",
                    reason="wall-clock throughput ratio; shared CI runners "
                           "are too noisy for a hard perf gate")
def test_sharded_throughput_gate_best_of_5():
    """Loose acceptance on a 2-core noisy box: best-of-5, sharded
    (2 replicas) must reach >= 0.9x the single-replica throughput at equal
    (num_actors, E). The forward is LATENCY-bound (a GIL-releasing sleep —
    what a real accelerator forward looks like from the host), so the
    single server loop serializes forwards while replicas overlap them:
    the GA3C single-predictor regime sharding exists for, measurable on a
    2-core box because overlapping waits needs no extra cores. (A
    CPU-bound forward is NOT shardable here: numpy's BLAS already uses
    both cores, so replicas would only oversubscribe — measured and
    rejected as a gate workload.)"""

    def latency_policy(obs, ids):
        time.sleep(0.005)                     # the "device forward"
        flat = np.abs(obs.reshape(obs.shape[0], -1))
        return (flat.sum(axis=1) * 997.0).astype(np.int64) \
            % CatchEnv.num_actions

    def run_once(num_replicas):
        sys_ = SeedSystem(env_factory=CatchEnv, policy_step=latency_policy,
                          num_actors=4, unroll=8, envs_per_actor=2,
                          deadline_ms=1.0, num_replicas=num_replicas)
        sys_.warmup()
        stats = sys_.run(seconds=0.8, with_learner=False)
        assert stats["inference_error"] is None, stats["inference_error"]
        return stats["env_frames_per_s"]

    time.sleep(0.3)       # let prior tests' teardown (spawned hosts,
    best_rel = 0.0        # daemon threads) settle off the 2 cores
    for _ in range(5):
        single = run_once(1)
        sharded = run_once(2)
        best_rel = max(best_rel, sharded / max(single, 1e-9))
        if best_rel >= 1.0:
            break
    assert best_rel >= 0.9, \
        f"sharded inference {best_rel:.2f}x single-replica: sharding regressed"
