"""Fault-tolerance behaviours: supervisor restart, straggler detection,
inference batching deadline, and generation smoke."""

import queue
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.inference import InferenceServer
from repro.launch.ft import HeartbeatMonitor, SimulatedFailure, Supervisor


def test_supervisor_restarts_from_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    calls = {"n": 0}

    def make_state():
        return {"w": jnp.zeros((2,)), "step": jnp.array(0)}

    def train_loop(state, start):
        for i in range(start, 10):
            state = {"w": state["w"] + 1.0, "step": jnp.array(i + 1)}
            if i == 4 and calls["n"] == 0:
                calls["n"] += 1
                mgr.save(state, i + 1)
                raise SimulatedFailure("boom")
        return state

    sup = Supervisor(mgr, max_restarts=2)
    final = sup.run(make_state, train_loop)
    assert int(final["step"]) == 10
    assert len(sup.restarts) == 1
    # progress was preserved: exactly 10 increments happened in total
    assert float(final["w"][0]) == 10.0


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)

    def train_loop(state, start):
        raise SimulatedFailure("always")

    sup = Supervisor(mgr, max_restarts=2)
    with pytest.raises(RuntimeError, match="restarts"):
        sup.run(lambda: {"w": jnp.zeros(())}, train_loop)


def test_inference_deadline_closes_partial_batches():
    seen = []

    def policy_step(obs, ids):
        seen.append(len(ids))
        return np.zeros((obs.shape[0],), np.int32)

    srv = InferenceServer(policy_step, max_batch=64, deadline_ms=5.0)
    srv.start()
    reply = srv.submit(0, np.zeros((4,), np.float32))
    action = reply.get(timeout=2.0)
    srv.stop()
    assert action == 0
    assert seen and seen[0] == 1          # batch closed at deadline, not at 64


def test_heartbeat_monitor_flags_stalled_actor():
    class FakeActor:
        def __init__(self, i):
            self.actor_id = i
            self.steps = 0

    actors = [FakeActor(0), FakeActor(1)]
    mon = HeartbeatMonitor(stall_s=0.05)
    assert mon.check(actors) == []
    actors[0].steps = 5                   # actor 0 progresses, actor 1 stalls
    time.sleep(0.08)
    assert mon.check(actors) == [1]


def test_greedy_generate_smoke():
    from repro.configs.registry import make_model, smoke_config
    from repro.launch.serve import greedy_generate
    cfg = smoke_config("qwen2.5-32b")
    bundle = make_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jnp.ones((2, 6), jnp.int32)
    out = greedy_generate(bundle, params, {"tokens": toks}, steps=5,
                          max_len=32, dtype=jnp.float32)
    assert out.shape == (2, 5)
    assert int(out.max()) < cfg.padded_vocab
